"""Party-scoped Federation lifecycle: build → train → checkpoint/resume →
serve. Covers the party handles, per-party checkpoint isolation (the
server's directory contains zero client leaves and vice versa),
mid-training resume equivalence (ledger + DP totals exactly continued),
the split serve plane (fed.decode == global decode, serve traffic in the
ledger), and the RDP accountant."""
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import VFLConfig, get_config, reduced
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core.async_engine import EngineConfig
from repro.core.privacy import GaussianLossChannel, Ledger, serve_messages
from repro.federation import Federation, SessionState, Transport
from repro.models import common
from repro.models.model_api import build_cache_specs, build_model
from repro.optim import sgd

SEQ = 16


def tiny_cfg(**overrides):
    return reduced(get_config("phi3-mini-3.8b"), d_model=64, n_heads=2,
                   n_kv_heads=1, d_ff=128, vocab_size=256, **overrides)


@pytest.fixture(scope="module")
def lm_session():
    cfg = tiny_cfg()
    fed = Federation.build(cfg, VFLConfig(), EngineConfig(method="cascaded"),
                           n_clients=2, seq_len=SEQ)
    return cfg, fed


# ---------------------------------------------------- party handles -------

def test_parties_engine_layout(lm_session):
    cfg, fed = lm_session
    params = fed.init_params(jax.random.key(0))
    parties = fed.parties
    assert len(parties) == 3 and parties.server.name == "server"
    server = parties.server.owned(params)
    assert "embed" not in server and "lm_head" in server
    c0 = parties.clients[0].owned(params)
    assert c0["embed"]["table"].shape == (cfg.padded_vocab, cfg.d_model)
    assert jnp.array_equal(c0["embed"]["table"],
                           params["clients"]["embed"]["table"][0])
    # the split reassembles losslessly
    rebuilt = parties.assemble(server, [p.owned(params)
                                        for p in parties.clients])
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rebuilt)):
        assert jnp.array_equal(a, b)


def test_parties_global_layout(lm_session):
    cfg, fed = lm_session
    gp = common.materialize(build_model(cfg, max_seq=SEQ).param_specs,
                            jax.random.key(1))
    parties = fed.parties
    server = parties.server.owned(gp)
    client = parties.clients[0].owned(gp)
    assert set(client) == {"embed"} and "embed" not in server
    merged = parties.merge_global(server, client)
    assert set(merged) == set(gp)


# ------------------------------------- per-party checkpoint isolation -----

def _npz_keys(path, party_dir):
    return list(np.load(os.path.join(path, party_dir, "arrays.npz")).files)


def test_checkpoint_isolation_engine_layout(lm_session, tmp_path):
    """ISSUE acceptance: flatten the server party's checkpoint — no
    client-owned leaf key appears, and vice versa."""
    cfg, fed = lm_session
    params = fed.init_params(jax.random.key(0))
    path = fed.save(str(tmp_path / "ck"), params, step=7)
    assert sorted(os.listdir(path)) == ["client_00", "client_01",
                                        "server", "session.json"]
    server_keys = _npz_keys(path, "server")
    assert server_keys and not any(k.startswith("embed")
                                   for k in server_keys)
    for m in range(2):
        ckeys = _npz_keys(path, f"client_{m:02d}")
        assert ckeys == ["embed::table"]
        assert not any(k.startswith(("lm_head", "blocks", "final_norm"))
                       for k in ckeys)


def test_checkpoint_isolation_global_layout(lm_session, tmp_path):
    cfg, fed = lm_session
    model = build_model(cfg, max_seq=SEQ)
    gp = common.materialize(model.param_specs, jax.random.key(1))
    opt = sgd(0.1, momentum=0.9)
    path = fed.save(str(tmp_path / "ck"), gp, step=3,
                    opt_state=opt.init(gp))
    assert not any(k.startswith("embed") for k in _npz_keys(path, "server"))
    assert all(k.startswith("embed") for k in _npz_keys(path, "clients"))
    # the optimizer's momentum tree splits on the same boundary
    assert not any("embed" in k for k in _npz_keys(path, "opt_server"))
    assert all("embed" in k for k in _npz_keys(path, "opt_clients"))


# ----------------------------------------------- save/restore roundtrip ---

def test_save_restore_roundtrip(lm_session, tmp_path):
    cfg, fed0 = lm_session
    noise = GaussianLossChannel(clip=5.0, epsilon=0.5, accountant="rdp")
    fed = Federation.build(cfg, VFLConfig(zoo_queries=2),
                           EngineConfig(method="cascaded"), n_clients=2,
                           seq_len=SEQ, noise=noise)
    params = fed.init_params(jax.random.key(0))
    ledger = fed.transport.account(batch=4, embed=cfg.d_model, n_rounds=5,
                                   zoo_queries=2)
    path = fed.save(str(tmp_path / "ck"), params, step=5, ledger=ledger,
                    dp_releases=30)
    fed2, params2, state = Federation.restore(path)
    assert state.step == 5 and state.dp_releases == 30
    assert state.ledger.total_bytes == ledger.total_bytes
    assert state.ledger.bytes_by_kind() == ledger.bytes_by_kind()
    assert fed2.transport == fed.transport          # incl. the DP channel
    assert fed2.vfl == fed.vfl and fed2.model_cfg == cfg
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        assert jnp.array_equal(a, b) and a.dtype == b.dtype
    assert state.dp_spent(fed2.transport) == noise.spent(30)


def test_restore_paper_mlp_session(tmp_path):
    cfg = PaperMLPConfig(n_features=16, n_classes=3, n_clients=2,
                         client_embed=8, server_embed=8)
    fed = Federation.build(cfg, VFLConfig(), EngineConfig())
    params = fed.init_params(jax.random.key(0))
    fed2, params2, _ = Federation.restore(
        fed.save(str(tmp_path / "ck"), params))
    assert fed2.n_clients == 2
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        assert jnp.array_equal(a, b)


def test_restore_adapter_session_needs_model(tmp_path):
    from repro.core.adapters import mlp_adapter
    adapter = mlp_adapter(n_clients=2, features=8, client_embed=8, d_ff=16,
                          server_embed=8, n_classes=2)
    fed = Federation.build(adapter, VFLConfig(), EngineConfig())
    params = fed.init_params(jax.random.key(0))
    path = fed.save(str(tmp_path / "ck"), params)
    with pytest.raises(ValueError, match="adapter-built"):
        Federation.restore(path)
    fed2, params2, _ = Federation.restore(path, model_cfg=adapter)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        assert jnp.array_equal(a, b)


# ---------------------------------------------- mid-training resume -------

def test_train_resume_equivalence(tmp_path):
    """ISSUE acceptance: save at step k, restore, continue → allclose to
    the straight-through run at step 2k; ledger and (ε, δ) totals exactly
    continued."""
    from repro.checkpoint import load_tree
    from repro.launch.train import train

    noise = GaussianLossChannel(clip=10.0, epsilon=1.0)
    kw = dict(batch=4, seq=SEQ, log_every=1000, noise=noise)
    A = str(tmp_path / "straight")
    B1, B2 = str(tmp_path / "half"), str(tmp_path / "resumed")
    ra = train("phi3-mini-3.8b", steps=4, checkpoint_path=A, **kw)
    train("phi3-mini-3.8b", steps=2, checkpoint_path=B1, **kw)
    rb = train(steps=4, resume=B1, checkpoint_path=B2, log_every=1000)
    assert rb["start_step"] == 2

    for party in ("server", "clients"):
        ta, _, _ = load_tree(os.path.join(A, party))
        tb, _, _ = load_tree(os.path.join(B2, party))
        for (ka, a), (_, b) in zip(
                jax.tree_util.tree_leaves_with_path(ta),
                jax.tree_util.tree_leaves_with_path(tb)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=2e-5, atol=2e-5, err_msg=f"{party}{ka}")
    ma = json.load(open(os.path.join(A, "session.json")))
    mb = json.load(open(os.path.join(B2, "session.json")))
    assert ma["ledger_counts"] == mb["ledger_counts"]
    assert ma["dp_releases"] == mb["dp_releases"]
    assert ma["dp_spent"] == mb["dp_spent"]
    assert ra["dp_epsilon"] == rb["dp_epsilon"]
    # optimizer's step clock continued, not reset (the bug this fixes)
    opt_s, _, _ = load_tree(os.path.join(B2, "opt_server"))
    assert int(opt_s["step"]) == 4


def test_train_resume_keeps_schedule_horizon(tmp_path):
    """A decaying schedule must continue the ORIGINAL total_steps on
    resume, not silently re-stretch to the new total."""
    from repro.launch.train import train
    p1, p2 = str(tmp_path / "a"), str(tmp_path / "b")
    train("phi3-mini-3.8b", steps=2, batch=2, seq=SEQ, schedule="cosine",
          log_every=1000, checkpoint_path=p1)
    train(steps=4, resume=p1, checkpoint_path=p2, log_every=1000)
    meta1 = json.load(open(os.path.join(p1, "session.json")))["metadata"]
    meta2 = json.load(open(os.path.join(p2, "session.json")))["metadata"]
    assert meta1["schedule_total_steps"] == 2
    assert meta2["schedule_total_steps"] == 2      # horizon preserved
    assert meta2["schedule"] == "cosine"


def test_train_resume_rejects_exhausted_steps(tmp_path):
    from repro.launch.train import train
    p = str(tmp_path / "ck")
    train("phi3-mini-3.8b", steps=2, batch=4, seq=SEQ, log_every=1000,
          checkpoint_path=p)
    with pytest.raises(ValueError, match="total step count"):
        train(steps=2, resume=p)


# -------------------------------------------------- serve plane -----------

def _global_greedy_decode(cfg, model, gp, toks, gen_len, key, temperature):
    """The pre-session serve loop (launch/serve.py), inlined as oracle."""
    B, prompt_len = toks.shape
    max_seq = prompt_len + gen_len
    caches = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        build_cache_specs(cfg, B, max_seq),
        is_leaf=lambda x: hasattr(x, "logical"))
    decode = jax.jit(model.decode_fn, donate_argnums=(2,))
    logits = None
    for t in range(prompt_len):
        logits, caches = decode(gp, {"tokens": toks[:, t:t + 1]}, caches, t)
    out = []
    for t in range(prompt_len, max_seq):
        lg = logits[:, -1].astype(jnp.float32)
        if temperature > 0:
            nxt = jax.random.categorical(jax.random.fold_in(key, 100 + t),
                                         lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        nxt = jnp.minimum(nxt, cfg.vocab_size - 1).astype(jnp.int32)
        out.append(np.asarray(nxt))
        logits, caches = decode(gp, {"tokens": nxt[:, None]}, caches, t)
    return np.stack(out, axis=1)


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_decode_matches_global_serve(temperature):
    """ISSUE acceptance: fed.decode runs split inference with the
    training party split and matches global decode token for token."""
    cfg = tiny_cfg()
    B, PL, GL = 2, 4, 4
    fed = Federation.build(cfg, VFLConfig(), EngineConfig(), n_clients=2,
                           seq_len=PL + GL)
    model = build_model(cfg, max_seq=PL + GL)
    key = jax.random.key(0)
    gp = common.materialize(model.param_specs, key)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, PL), 0,
                              cfg.vocab_size)
    res = fed.decode(gp, toks, gen_len=GL, temperature=temperature, key=key)
    ref = _global_greedy_decode(cfg, model, gp, toks, GL, key, temperature)
    np.testing.assert_array_equal(res.tokens, ref)


def test_decode_wire_accounting():
    """Serve-time messages land in the ledger EXACTLY: one embedding up
    per decode call, token ids down only on the gen_len generation steps
    (the clients already own the prompt), never a gradient."""
    cfg = tiny_cfg()
    B, PL, GL = 2, 3, 5
    fed = Federation.build(cfg, VFLConfig(), EngineConfig(), n_clients=2,
                           seq_len=PL + GL)
    params = fed.init_params(jax.random.key(0))
    prior = Ledger()
    prior.messages.extend(serve_messages(B, cfg.d_model))   # pre-existing
    res = fed.decode(params, jnp.zeros((B, PL), jnp.int32), gen_len=GL,
                     ledger=prior)
    up, token = serve_messages(B, cfg.d_model)
    assert res.ledger is prior                      # extended, not replaced
    assert res.wire_bytes == ((PL + GL + 1) * up.nbytes
                              + (GL + 1) * token.nbytes)
    assert not res.transmits_gradients
    by_kind = res.ledger.bytes_by_kind()
    assert by_kind == {"embedding": (PL + GL + 1) * up.nbytes,
                       "token": (GL + 1) * token.nbytes}


def test_save_rejects_party_count_mismatch(tmp_path):
    """An adapter session whose stacked client dim disagrees with the
    session's n_clients must refuse a per-party save (rows would be
    silently dropped)."""
    from repro.core.adapters import mlp_adapter
    adapter = mlp_adapter(n_clients=4, features=8, client_embed=8, d_ff=16,
                          server_embed=8, n_classes=2)
    fed = Federation.build(adapter, VFLConfig(), EngineConfig())  # default 2
    params = adapter.init_params(jax.random.key(0))
    with pytest.raises(ValueError, match="n_clients=4"):
        fed.save(str(tmp_path / "ck"), params)
    fed4 = Federation.build(adapter, VFLConfig(), EngineConfig(),
                            n_clients=4)
    fed4.save(str(tmp_path / "ck"), params)
    assert sorted(p for p in os.listdir(tmp_path / "ck")
                  if p.startswith("client")) == [
        "client_00", "client_01", "client_02", "client_03"]


def test_decode_validation(lm_session):
    cfg, fed = lm_session
    params = fed.init_params(jax.random.key(0))
    with pytest.raises(ValueError, match="seq_len"):
        fed.decode(params, jnp.zeros((1, SEQ), jnp.int32), gen_len=4)
    tab = Federation.build(
        PaperMLPConfig(n_features=16, n_classes=3, n_clients=2,
                       client_embed=8, server_embed=8),
        VFLConfig(), EngineConfig())
    with pytest.raises(ValueError, match="serve plane"):
        tab.decode({"clients": {}, "server": {}},
                   jnp.zeros((1, 2), jnp.int32), gen_len=1)


def test_serve_driver_federated_equals_global():
    """launch/serve.py's split path and its global shim agree token for
    token (replicated client tables ⇒ identical model function)."""
    from repro.launch.serve import serve
    kw = dict(batch=2, prompt_len=4, gen_len=4, temperature=0.8)
    fed_res = serve("phi3-mini-3.8b", n_clients=2, **kw)
    glob_res = serve("phi3-mini-3.8b", n_clients=0, **kw)
    assert fed_res["mode"] == "federated" and glob_res["mode"] == "global"
    assert fed_res["sample_output"] == glob_res["sample_output"]
    assert fed_res["wire_bytes"] > 0 and not fed_res["wire_has_gradients"]


# ---------------------------------------------------- RDP accountant ------

def test_rdp_accountant_tighter_for_many_releases():
    basic = GaussianLossChannel(clip=1.0, epsilon=0.1, delta=1e-6)
    rdp = GaussianLossChannel(clip=1.0, epsilon=0.1, delta=1e-6,
                              accountant="rdp")
    assert rdp.sigma == basic.sigma            # same mechanism, same noise
    assert rdp.spent(0) == (0.0, 0.0)
    for k in (1_000, 10_000, 100_000):
        e_basic, d_basic = basic.spent(k)
        e_rdp, d_rdp = rdp.spent(k)
        assert 0 < e_rdp < e_basic < math.inf
        assert d_rdp == 1e-6 <= d_basic        # δ, not (k+1)δ
    # monotone in k
    es = [rdp.spent(k)[0] for k in (10, 100, 1_000)]
    assert es == sorted(es)


def test_rdp_accountant_validation():
    with pytest.raises(ValueError, match="accountant"):
        GaussianLossChannel(accountant="pld")
    # selectable through the Transport / session plumbing
    ch = GaussianLossChannel(clip=5.0, epsilon=0.5, accountant="rdp")
    t = Transport("cascaded", noise=ch)
    eps, delta = t.privacy_spent(1000)
    assert np.isfinite(eps) and delta == ch.delta


def test_session_state_defaults():
    s = SessionState()
    assert s.step == 0 and s.ledger.total_bytes == 0
    assert s.dp_spent(Transport("cascaded")) == (math.inf, 0.0)
    assert s.async_state is None


# ------------------------------------- durable async plane (wire plane) ---

def test_population_resume_under_faults(tmp_path):
    """ISSUE acceptance: kill a faulted ``run_population`` at round k,
    ``fed.save`` the async plane, ``Federation.restore``, continue — the
    combined trace is the straight-through run bitwise, with ledger
    multiset/byte totals and the DP budget exactly continued."""
    import collections

    from repro.configs.paper_mlp import PaperMLPConfig
    from repro.data import make_classification, vertical_partition
    from repro.wire import FaultPlan

    cfg = PaperMLPConfig(n_features=32, n_classes=4, n_clients=4,
                         client_embed=16, server_embed=32)
    X, y = make_classification(0, 256, cfg.n_features, cfg.n_classes)
    Xp = jnp.asarray(vertical_partition(X, cfg.n_clients))
    y = jnp.asarray(y)
    noise = GaussianLossChannel(clip=10.0, epsilon=1.0)
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05)
    ec = EngineConfig(method="cascaded", steps=16, batch_size=8)
    plan = FaultPlan(seed=5, drop=0.25, latency_ms=2.0, max_retries=1)

    fed = Federation.build(cfg, vfl, ec, noise=noise)
    params = fed.init_params(jax.random.key(0))
    full = fed.run_population(params, Xp, y, fault_plan=plan)

    half = fed.run_population(params, Xp, y, fault_plan=plan, until=7)
    path = fed.save(str(tmp_path / "ck"), half.params,
                    step=half.state.step, ledger=half.ledger,
                    dp_releases=half.dp_releases,
                    async_state=half.state)
    manifest = json.load(open(os.path.join(path, "session.json")))
    assert manifest["async_plane"] is True
    assert os.path.isdir(os.path.join(path, "async_plane"))

    fed2, params2, state = Federation.restore(path)
    assert state.async_state is not None and state.async_state.step == 7
    cont = fed2.run_population(params2, Xp, y, fault_plan=plan,
                               state=state.async_state,
                               ledger=state.ledger,
                               dp_releases=state.dp_releases)
    assert np.array_equal(full.losses[7:], cont.losses)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(full.params),
            jax.tree_util.tree_leaves_with_path(cont.params)):
        assert jnp.array_equal(a, b), pa
    np.testing.assert_array_equal(full.state.delays, cont.state.delays)
    np.testing.assert_array_equal(full.state.last_active,
                                  cont.state.last_active)
    assert full.state.clock_ms == cont.state.clock_ms
    # accounting continues exactly: message multiset, byte totals, DP
    assert (collections.Counter(full.ledger.messages)
            == collections.Counter(cont.ledger.messages))
    assert full.serialized_bytes == cont.serialized_bytes
    assert full.dp_releases == cont.dp_releases
    assert (full.epsilon, full.delta) == (cont.epsilon, cont.delta)
    assert np.isfinite(cont.epsilon)
    # the faults actually fired across the kill point
    assert (cont.stats["uplink_drops"] + cont.stats["downlink_drops"]) > 0
