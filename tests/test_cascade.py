"""Cascade step semantics (paper Alg. 1) and baselines factory."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import VFLConfig
from repro.core import cascade
from repro.core.partition import merge_params, split_params
from repro.optim import sgd

CLIENT_KEYS = ("embed",)


def make_toy():
    key = jax.random.key(0)
    params = {
        "embed": {"w": jax.random.normal(key, (8, 4)) * 0.3},
        "head": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                        (4, 3)) * 0.3},
    }
    x = jax.random.randint(jax.random.fold_in(key, 2), (16,), 0, 8)
    y = jax.random.randint(jax.random.fold_in(key, 3), (16,), 0, 3)

    def loss_fn(p, batch):
        h = jnp.take(p["embed"]["w"], batch["x"], axis=0)
        logits = h @ p["head"]["w"]
        lse = jax.scipy.special.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], -1)[:, 0]
        return jnp.mean(lse - gold), {}

    return params, {"x": x, "y": y}, loss_fn


def test_server_grad_matches_foo():
    """The cascade's server update is EXACT backprop on w0 (Eq. 4)."""
    params, batch, loss_fn = make_toy()
    vfl = VFLConfig(mu=1e-4, lr_server=0.1, lr_client=0.1)
    opt = sgd(0.1)
    step = cascade.make_cascaded_step(loss_fn, CLIENT_KEYS, vfl, opt)
    new_params, _, out = jax.jit(step)(params, opt.init(params), batch,
                                       jax.random.key(1))
    # reference: pure FOO update of the server partition
    g = jax.grad(lambda p: loss_fn(p, batch)[0])(params)
    want = params["head"]["w"] - 0.1 * g["head"]["w"]
    np.testing.assert_allclose(np.asarray(new_params["head"]["w"]),
                               np.asarray(want), rtol=1e-5)


def test_client_update_magnitude_matches_estimator():
    """ZOO client update = -lr·φ(d)/μ·(ĥ−h)·u with ‖u‖=1 (sphere), so its
    norm must equal lr·φ/μ·|ĥ−h| exactly (Eq. 3)."""
    params, batch, loss_fn = make_toy()
    mu, lr = 1e-3, 0.05
    vfl = VFLConfig(mu=mu, zoo_dist="sphere", lr_server=lr, lr_client=lr)
    opt = sgd(lr)
    step = cascade.make_cascaded_step(loss_fn, CLIENT_KEYS, vfl, opt)
    new_params, _, out = jax.jit(step)(params, opt.init(params), batch,
                                       jax.random.key(2))
    delta = np.asarray(new_params["embed"]["w"] - params["embed"]["w"])
    d = 8 * 4
    want = lr * d / mu * abs(float(out.loss_perturbed - out.loss))
    got = float(np.linalg.norm(delta))
    np.testing.assert_allclose(got, want, rtol=2e-3)


def test_cascaded_descends_in_expectation():
    """Averaged over seeds, the cascaded step reduces the loss."""
    params, batch, loss_fn = make_toy()
    vfl = VFLConfig(mu=1e-3, lr_server=0.2, lr_client=0.02)
    opt = sgd(0.2)
    step = jax.jit(cascade.make_cascaded_step(loss_fn, CLIENT_KEYS, vfl, opt))
    l0 = float(loss_fn(params, batch)[0])
    losses = []
    for s in range(16):
        p2, _, _ = step(params, opt.init(params), batch, jax.random.key(s))
        losses.append(float(loss_fn(p2, batch)[0]))
    assert np.mean(losses) < l0


def test_full_zoo_step_touches_both_partitions():
    params, batch, loss_fn = make_toy()
    vfl = VFLConfig(mu=1e-3, lr_server=0.01, lr_client=0.01)
    opt = sgd(0.01)
    step = cascade.make_full_zoo_step(loss_fn, CLIENT_KEYS, vfl, opt)
    p2, _, out = jax.jit(step)(params, opt.init(params), batch,
                               jax.random.key(0))
    assert np.any(np.asarray(p2["embed"]["w"]) != np.asarray(params["embed"]["w"]))
    assert np.any(np.asarray(p2["head"]["w"]) != np.asarray(params["head"]["w"]))


def test_method_factory():
    params, batch, loss_fn = make_toy()
    vfl = VFLConfig()
    opt = sgd(0.01)
    for m in ["cascaded", "vafl", "split-learning", "zoo-vfl", "syn-zoo-vfl"]:
        step = cascade.make_step_for_method(m, loss_fn, CLIENT_KEYS, vfl, opt)
        p2, _, out = jax.jit(step)(params, opt.init(params), batch,
                                   jax.random.key(0))
        assert np.isfinite(float(out.loss))
    with pytest.raises(ValueError):
        cascade.make_step_for_method("sgd-vfl", loss_fn, CLIENT_KEYS, vfl, opt)


def test_split_merge_roundtrip():
    params, _, _ = make_toy()
    c, s = split_params(params, CLIENT_KEYS)
    assert set(c) == {"embed"} and set(s) == {"head"}
    m = merge_params(c, s)
    assert set(m) == set(params)
