"""MoE dispatch equivalence + capacity semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import get_config, reduced
from repro.models import common, moe


def make(cf=8.0, groups=4):
    cfg = reduced(get_config("qwen3-moe-30b-a3b"), capacity_factor=cf,
                  moe_groups=groups)
    p = common.materialize(moe.moe_specs(cfg, cfg.d_model),
                           jax.random.key(0), dtype_override="float32")
    return cfg, p


def test_dispatch_matches_dense_no_drops():
    cfg, p = make(cf=8.0)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y1, a1 = moe.moe_apply_dispatch(cfg, p, x)
    y2, a2 = moe.moe_apply_dense(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=1e-3)
    assert abs(float(a1 - a2)) < 1e-6


def test_gather_path_matches_dense_single_token():
    cfg, p = make()
    x = jax.random.normal(jax.random.key(2), (2, 1, cfg.d_model))
    y1, _ = moe.moe_apply_gather(cfg, p, x)
    y2, _ = moe.moe_apply_dense(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=1e-3)


def test_capacity_drops_reduce_output_energy():
    cfg_hi, p = make(cf=8.0)
    cfg_lo, _ = make(cf=0.25)
    x = jax.random.normal(jax.random.key(3), (2, 32, cfg_hi.d_model))
    y_hi, _ = moe.moe_apply_dispatch(cfg_hi, p, x)
    y_lo, _ = moe.moe_apply_dispatch(dataclasses.replace(cfg_lo), p, x)
    assert float(jnp.sum(jnp.square(y_lo))) < float(jnp.sum(jnp.square(y_hi)))


def test_router_topk_gates_normalized():
    cfg, p = make()
    x = jax.random.normal(jax.random.key(4), (2, 8, cfg.d_model))
    gates, idx, aux = moe._router(cfg, p, x)
    np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)), 1.0,
                               atol=1e-5)
    assert int(jnp.max(idx)) < cfg.n_experts
    assert float(aux) >= 0


@settings(max_examples=10, deadline=None)
@given(S=st.sampled_from([8, 16, 32]), B=st.integers(1, 3),
       seed=st.integers(0, 2**16))
def test_dispatch_dense_equivalence_property(S, B, seed):
    """Property: for any batch shape/seed, grouped gather-dispatch ==
    dense masked loop when capacity is ample."""
    cfg, p = make(cf=8.0, groups=4)
    x = jax.random.normal(jax.random.key(seed), (B, S, cfg.d_model)) * 0.7
    y1, _ = moe.moe_apply_dispatch(cfg, p, x)
    y2, _ = moe.moe_apply_dense(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=3e-4, rtol=2e-3)


def test_moe_backward_finite():
    cfg, p = make(cf=1.25)
    x = jax.random.normal(jax.random.key(5), (2, 16, cfg.d_model))

    def loss(p_):
        y, aux = moe.moe_apply_dispatch(cfg, p_, x)
        return jnp.mean(jnp.square(y)) + aux
    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
