"""Federation session API: back-compat equivalence, Transport semantics,
and the DP loss channel (GaussianLossChannel + accountant)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import VFLConfig
from repro.configs.paper_mlp import PaperMLPConfig
from repro.core import async_engine, cascade
from repro.core.methods import METHOD_ALIASES
from repro.core.privacy import GaussianLossChannel, round_messages
from repro.data import make_classification, vertical_partition
from repro.federation import Federation, Transport
from repro.launch.train import build_parser
from repro.models import common, tabular
from repro.optim import sgd


@pytest.fixture(scope="module")
def setup():
    cfg = PaperMLPConfig(n_features=32, n_classes=4, n_clients=4,
                         client_embed=16, server_embed=32)
    X, y = make_classification(0, 256, cfg.n_features, cfg.n_classes)
    Xp = jnp.asarray(vertical_partition(X, cfg.n_clients))
    params = common.materialize(tabular.param_specs(cfg), jax.random.key(0))
    return cfg, Xp, jnp.asarray(y), params


VFL = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05)
CHANNEL = GaussianLossChannel(clip=5.0, epsilon=0.5, delta=1e-5)


# ------------------------------------------------- back-compat shims ------

def test_session_bitwise_matches_engine_run(setup):
    """ISSUE acceptance: the tabular path through the new session API is
    bitwise-equal to the pre-redesign ``async_engine.run`` at noise=0."""
    cfg, Xp, y, params = setup
    ec = async_engine.EngineConfig(method="cascaded", steps=40, batch_size=8)
    old = async_engine.run(ec, VFL, params, Xp, y)
    new = Federation.build(cfg, VFL, ec).run(params, Xp, y)
    assert np.array_equal(old.losses, new.losses)
    for a, b in zip(jax.tree.leaves(old.params), jax.tree.leaves(new.params)):
        assert jnp.array_equal(a, b)
    assert old.wire_bytes == new.wire_bytes
    assert old.epsilon == new.epsilon == math.inf


def test_session_mesh_from_engine_cfg(setup):
    """The sharded path is picked from EngineConfig.mesh_shards, not a
    loose mesh= kwarg — and a 1-shard mesh stays bitwise-identical."""
    cfg, Xp, y, params = setup
    ec = async_engine.EngineConfig(method="cascaded", steps=15, batch_size=8)
    single = Federation.build(cfg, VFL, ec).run(params, Xp, y)
    ec_sh = async_engine.EngineConfig(method="cascaded", steps=15,
                                      batch_size=8, mesh_shards=1)
    fed = Federation.build(cfg, VFL, ec_sh)
    assert fed.mesh is not None and fed.mesh.shape["data"] == 1
    shard = fed.run(params, Xp, y)
    assert np.array_equal(single.losses, shard.losses)


def test_build_validation(setup):
    cfg, *_ = setup
    ec = async_engine.EngineConfig(method="cascaded")
    with pytest.raises(ValueError, match="not both"):
        Federation.build(cfg, VFL, ec, noise=CHANNEL,
                         transport=Transport("cascaded", noise=CHANNEL))
    with pytest.raises(ValueError, match="disagree"):
        Federation.build(cfg, VFL, ec, transport=Transport("vafl"))
    with pytest.raises(TypeError):
        Federation.build("paper-mlp", VFL, ec)
    with pytest.raises(ValueError, match="sync_step"):
        Federation.build(cfg, VFL, ec).sync_step(sgd(0.1))
    from repro.launch.mesh import make_client_mesh
    with pytest.raises(ValueError, match="mesh_shards"):
        Federation.build(cfg, VFL,
                         async_engine.EngineConfig(method="cascaded",
                                                   mesh_shards=1),
                         mesh=make_client_mesh(1))


def test_engine_rejects_noise_with_unrolled_oracle(setup):
    """Both planes refuse noise + the unrolled oracle the same way."""
    import dataclasses
    cfg, Xp, y, params = setup
    ec = async_engine.EngineConfig(method="cascaded", steps=2, batch_size=4)
    fed = Federation.build(cfg, dataclasses.replace(
        VFL, zoo_unrolled_oracle=True), ec, noise=CHANNEL)
    with pytest.raises(ValueError, match="oracle"):
        fed.run(params, Xp, y)


# ------------------------------------------------------- Transport --------

def test_transport_canonicalizes_method():
    assert Transport("ours").method == "cascaded"
    assert Transport("syn-zoo-vfl").method == "syn-zoo"
    with pytest.raises(ValueError):
        Transport("sgd-vfl")


def test_transport_rejects_noise_on_wrong_wires():
    with pytest.raises(ValueError, match="partial derivatives"):
        Transport("vafl", noise=CHANNEL)
    with pytest.raises(ValueError, match="sync"):
        Transport("syn-zoo", noise=CHANNEL)
    # async ZOO wires accept it
    assert Transport("cascaded", noise=CHANNEL).noise is CHANNEL
    assert Transport("zoo", noise=CHANNEL).method == "zoo-vfl"


def test_transport_downlink_identity_without_channel():
    losses = jnp.asarray([1.0, 2.0, 3.0])
    out = Transport("cascaded").downlink(losses, jax.random.key(0))
    assert out is losses


def test_transport_owns_ledger_accounting():
    t = Transport("cascaded")
    led = t.account(batch=8, embed=16, zoo_queries=3, n_clients=2,
                    n_rounds=5)
    per = sum(m.nbytes for m in round_messages("cascaded", 8, 16, 3))
    assert led.total_bytes == 10 * per
    assert not led.transmits_gradients


# ------------------------------------------------- DP loss channel --------

def test_gaussian_channel_sigma_calibration():
    ch = GaussianLossChannel(clip=2.0, epsilon=0.5, delta=1e-5)
    expect = 2.0 * math.sqrt(2.0 * math.log(1.25 / 1e-5)) / 0.5
    assert ch.sigma == pytest.approx(expect)
    for bad in (dict(clip=0.0), dict(epsilon=-1.0), dict(delta=1.5)):
        with pytest.raises(ValueError):
            GaussianLossChannel(**bad)


def test_gaussian_channel_clips_and_noises():
    ch = GaussianLossChannel(clip=1.0, epsilon=10_000.0, delta=1e-5)
    losses = jnp.asarray([5.0, -3.0, 0.5])
    out = np.asarray(ch.apply(losses, jax.random.key(0)))
    # at huge ε the noise is tiny: the clamp dominates
    np.testing.assert_allclose(out, [1.0, 0.0, 0.5], atol=0.01)


def test_accountant_composition():
    ch = GaussianLossChannel(clip=1.0, epsilon=0.1, delta=1e-6)
    assert ch.spent(0) == (0.0, 0.0)
    e1, d1 = ch.spent(1)
    assert (e1, d1) == (0.1, 1e-6)
    e_small, _ = ch.spent(100)
    e_big, d_big = ch.spent(10_000)
    assert 0 < e_small < e_big < math.inf
    # advanced composition beats basic for many small-ε releases
    assert e_big < 10_000 * ch.epsilon
    assert 0 < d_big < 1


def test_dp_run_reports_finite_budget(setup):
    """ISSUE acceptance: with the noise channel enabled the engine still
    keeps gradients off the wire and reports a finite spent (ε, δ)."""
    cfg, Xp, y, params = setup
    ec = async_engine.EngineConfig(method="cascaded", steps=30, batch_size=8)
    clean = Federation.build(cfg, VFL, ec).run(params, Xp, y)
    noisy = Federation.build(cfg, VFL, ec, noise=CHANNEL).run(params, Xp, y)
    assert np.isfinite(noisy.epsilon) and noisy.epsilon > 0
    assert 0 < noisy.delta < 1
    assert not noisy.transmits_gradients
    assert noisy.wire_bytes == clean.wire_bytes    # noise adds no bytes
    assert np.isfinite(noisy.losses).all()
    # the noisy wire perturbs the client updates -> different trajectory
    assert not np.array_equal(clean.losses, noisy.losses)


def test_dp_sync_cascade_step_noises_client_only(setup):
    """The cascade step factory's noise hook perturbs only what the
    client receives: the server partition's FOO update stays exact."""
    cfg, Xp, y, params = setup
    vfl = VFLConfig(mu=1e-3, lr_server=0.05, lr_client=0.05, zoo_queries=2)
    opt = sgd(0.05)
    batch = {"x_parts": Xp[:, :16], "y": y[:16]}
    outs = {}
    for name, transport in (("clean", Transport("cascaded")),
                            ("noisy", Transport("cascaded", noise=CHANNEL))):
        step = cascade.make_step_for_method(
            "cascaded", tabular.global_loss, tabular.CLIENT_KEYS, vfl, opt,
            transport=transport)
        outs[name] = jax.jit(step)(params, opt.init(params), batch,
                                   jax.random.key(3))[0]
    assert all(
        jnp.array_equal(a, b) for a, b in
        zip(jax.tree.leaves(outs["clean"]["server"]),
            jax.tree.leaves(outs["noisy"]["server"])))
    assert not all(
        jnp.array_equal(a, b) for a, b in
        zip(jax.tree.leaves(outs["clean"]["clients"]),
            jax.tree.leaves(outs["noisy"]["clients"])))


def test_step_factory_noise_validation():
    opt = sgd(0.05)
    with pytest.raises(NotImplementedError):
        cascade.make_step_for_method(
            "zoo-vfl", tabular.global_loss, tabular.CLIENT_KEYS, VFL, opt,
            transport=Transport("zoo-vfl", noise=CHANNEL))
    with pytest.raises(ValueError, match="transport method"):
        cascade.make_step_for_method(
            "zoo-vfl", tabular.global_loss, tabular.CLIENT_KEYS, VFL, opt,
            transport=Transport("cascaded"))
    import dataclasses
    with pytest.raises(ValueError, match="fused lane"):
        cascade.make_cascaded_step(
            tabular.global_loss, tabular.CLIENT_KEYS,
            dataclasses.replace(VFL, fused_dual=False), opt,
            transport=Transport("cascaded", noise=CHANNEL))


# ------------------------------------------------- CLI canonicalization ---

def test_cli_accepts_every_alias_spelling():
    """launch/train.py's argparse surface is the shared alias table; the
    driver canonicalizes before anything downstream sees the name."""
    parser = build_parser()
    choices = next(a.choices for a in parser._actions
                   if "--method" in a.option_strings)
    assert set(choices) == set(METHOD_ALIASES)
    for alias in METHOD_ALIASES:
        assert parser.parse_args(["--method", alias]).method == alias
