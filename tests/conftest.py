"""Shared test fixtures. NOTE: no XLA_FLAGS device-count override here —
smoke tests and benches must see the real single CPU device (the dry-run
sets its own flags in its own process)."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)


def tiny_batch(cfg, B=2, S=16, key=None):
    """Inputs for any family's reduced config."""
    key = key if key is not None else jax.random.key(7)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["tokens"] = toks[:, : S - cfg.n_vision_tokens]
        batch["labels"] = batch["tokens"]
        batch["patch_embeds"] = jnp.ones(
            (B, cfg.n_vision_tokens, cfg.frontend_dim), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.frontend_dim),
                                   jnp.bfloat16)
    return batch
