"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True
on CPU — the kernel body itself is executed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_chunk.ops import ssd_chunk
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
from repro.kernels.zoo_dual_matmul.ops import zoo_dual_matmul
from repro.kernels.zoo_dual_matmul.ref import zoo_dual_matmul_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BH,S,d", [(2, 128, 64), (4, 256, 64), (1, 256, 128)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(BH, S, d, dtype, causal, window):
    ks = jax.random.split(jax.random.key(S + d), 3)
    q, k, v = [jax.random.normal(ks[i], (BH, S, d), dtype) for i in range(3)]
    out = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_cross_lengths():
    """Sq != Skv (cross/prefix attention, non-causal)."""
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (2, 128, 64))
    k = jax.random.normal(ks[1], (2, 256, 64))
    v = jax.random.normal(ks[2], (2, 256, 64))
    out = flash_attention(q, k, v, causal=False, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,d", [(128, 256), (256, 512), (64, 1024)])
def test_rmsnorm_sweep(M, d, dtype):
    x = jax.random.normal(jax.random.key(M), (M, d), dtype)
    sc = jax.random.normal(jax.random.key(d), (d,), jnp.float32)
    out = rmsnorm(x, sc, bm=64)
    ref = rmsnorm_ref(x, sc)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N", [(128, 256, 128), (256, 128, 256),
                                   (64, 512, 384)])
def test_zoo_dual_matmul_sweep(M, K, N, dtype):
    ks = jax.random.split(jax.random.key(M + K + N), 3)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = jax.random.normal(ks[1], (K, N), dtype)
    u = jax.random.normal(ks[2], (K, N), dtype)
    mu = 1e-2
    y, y_hat = zoo_dual_matmul(x, w, u, mu, bm=64, bn=64)
    ry, ry_hat = zoo_dual_matmul_ref(x, w, u, mu)
    tol = 1e-4 if dtype == jnp.float32 else 1.5e-1
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ry, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(y_hat, np.float32),
                               np.asarray(ry_hat, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BH,S,P,N,chunk", [(2, 64, 32, 16, 16),
                                            (3, 128, 32, 16, 32),
                                            (1, 128, 64, 32, 64)])
def test_ssd_chunk_kernel_sweep(BH, S, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(S + P), 5)
    xh = (jax.random.normal(ks[0], (BH, S, P)) * 0.5).astype(dtype)
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (BH, S))) * 0.9 + 0.05
    dt = jax.nn.softplus(jax.random.normal(ks[2], (BH, S)))
    bm = (jax.random.normal(ks[3], (BH, S, N)) * 0.5).astype(dtype)
    cm = (jax.random.normal(ks[4], (BH, S, N)) * 0.5).astype(dtype)
    y = ssd_chunk(xh, a, dt, bm, cm, chunk=chunk)
    r = ssd_chunk_ref(xh, a, dt, bm, cm)
    tol = 1e-4 if dtype == jnp.float32 else 1.5e-1
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32),
                               atol=tol, rtol=tol)


def test_zoo_dual_matmul_perturbation_direction():
    """(ŷ − y)/μ must equal x@u — the quantity the ZOO estimator needs."""
    ks = jax.random.split(jax.random.key(9), 3)
    x = jax.random.normal(ks[0], (128, 128))
    w = jax.random.normal(ks[1], (128, 128))
    u = jax.random.normal(ks[2], (128, 128))
    y, y_hat = zoo_dual_matmul(x, w, u, 1e-3)
    np.testing.assert_allclose(np.asarray((y_hat - y) / 1e-3),
                               np.asarray(x @ u), atol=1e-2, rtol=1e-2)
